"""repro.vision subsystem tests: models from workload tables, GemmConfig
routing (algo/impl/quantized/block=auto all apply to convs), BN folding, and
the conv autotuning integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workloads
from repro.core.gemm import GemmConfig, use_gemm
from repro.kernels import ops as kops
from repro.vision import layers as vl
from repro.vision import models as vm


@pytest.fixture(scope="module")
def alexnet_small():
    model = vm.build("alexnet", num_classes=10, image_size=67, width_div=8)
    params = vm.init_params(model, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 67, 67, 3))
    return model, params, x


def test_model_tables_match_workloads():
    """The runnable models take their conv topology FROM the workload
    tables: at canonical resolution every conv matches its ConvSpec."""
    model = vm.build("alexnet")
    specs = {s.name: s for s in workloads.alexnet_convs()}
    convs = {c.name: c for c in vm.conv_layers(model)}
    assert set(convs) == set(specs)
    for name, spec in specs.items():
        c = convs[name]
        assert (c.kh, c.kw, c.cin, c.cout, c.stride, c.pad, c.groups) == \
            (spec.kh, spec.kw, spec.cin, spec.cout, spec.stride, spec.pad,
             spec.groups), name
    # and the spec-derived GEMM table still is the Tables-1-3 table
    g = workloads.alexnet(batch=2)
    assert (g[0].m, g[0].k, g[0].n) == (2 * 55 * 55, 363, 96)
    assert (g[1].m, g[1].k, g[1].n) == (2 * 27 * 27, 5 * 5 * 48, 128)


def test_resnet50_table_has_bottleneck_structure():
    specs = workloads.CONV_SPECS["resnet50"]()
    names = [s.name for s in specs]
    assert names[0] == "conv1"
    assert "s2b1.proj" in names and "s5b3.c3" in names
    # 1 stem + 16 blocks x 3 convs + 4 projection shortcuts = 53
    assert len(specs) == 53


@pytest.mark.parametrize("name,size", [("alexnet", 67), ("vgg16", 32),
                                       ("resnet50", 32)])
def test_model_forward_shapes(name, size):
    model = vm.build(name, num_classes=7, image_size=size, width_div=16)
    params = vm.init_params(model, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, size, size, 3))
    logits = vm.apply(model, params, x)
    assert logits.shape == (2, 7)
    assert bool(jnp.isfinite(logits).all())


def test_fused_pallas_model_matches_xla(alexnet_small):
    model, params, x = alexnet_small
    ref = vm.apply(model, params, x)
    for algo in ("baseline", "fip", "ffip"):
        with use_gemm(GemmConfig(algo=algo, impl="pallas")):
            got = vm.apply(model, params, x)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_materializing_provider_path_matches(alexnet_small):
    """impl=xla with fip/ffip routes convs through conv2d_via_gemm + the
    provider algebra — same answer as the default path."""
    model, params, x = alexnet_small
    ref = vm.apply(model, params, x)
    with use_gemm(GemmConfig(algo="ffip", impl="xla")):
        got = vm.apply(model, params, x)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_quantized_model_fused_equals_reference(alexnet_small):
    """quantized=True + 'q' entries: the pallas (fused) and xla
    (materializing) integer paths agree bit-for-bit, and approximate the
    float model."""
    model, params, x = alexnet_small
    qparams = vm.attach_quantized(model, params)
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", quantized=True)):
        fused = vm.apply(model, qparams, x)
    with use_gemm(GemmConfig(algo="ffip", impl="xla", quantized=True)):
        ref = vm.apply(model, qparams, x)
    assert (np.asarray(fused) == np.asarray(ref)).all()
    float_logits = vm.apply(model, params, x)
    rel = float(jnp.linalg.norm(fused - float_logits)
                / (jnp.linalg.norm(float_logits) + 1e-9))
    assert rel < 0.35


def test_quantized_without_q_falls_back_to_float(alexnet_small):
    model, params, x = alexnet_small
    ref = vm.apply(model, params, x)
    with use_gemm(GemmConfig(algo="ffip", quantized=True)):
        got = vm.apply(model, params, x)       # no "q" entries attached
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_fold_bn_exact():
    """fold_bn(conv, bn) == conv -> batchnorm, to float rounding."""
    key = jax.random.PRNGKey(0)
    p = vl.conv_init(key, 3, 3, 4, 8)
    rng = np.random.RandomState(0)
    bn = {"gamma": jnp.asarray(rng.uniform(0.5, 2.0, 8), jnp.float32),
          "beta": jnp.asarray(rng.standard_normal(8), jnp.float32),
          "mean": jnp.asarray(rng.standard_normal(8), jnp.float32),
          "var": jnp.asarray(rng.uniform(0.2, 3.0, 8), jnp.float32)}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 9, 4))
    want = vl.batchnorm(vl.conv2d(x, p, pad=1), bn)
    folded = vl.fold_bn(p, bn)
    got = vl.conv2d(x, folded, pad=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attach_quantized_fc_skips_odd_k():
    from repro.models.layers import dense_init
    p_even = dense_init(jax.random.PRNGKey(0), 6, 4, jnp.float32)
    p_odd = dense_init(jax.random.PRNGKey(0), 7, 4, jnp.float32)
    assert "q" in vl.attach_quantized_fc(p_even)
    assert "q" not in vl.attach_quantized_fc(p_odd)


def test_conv_geometries_track_spatial_flow():
    model = vm.build("resnet50", num_classes=4, image_size=32, width_div=16)
    geoms = vm.conv_geometries(model, 32)
    convs = vm.conv_layers(model)
    assert [g[0] for g in geoms] == convs
    # the stem sees the full image; everything after the pool is smaller
    assert geoms[0][1] == 32
    assert all(g[1] <= 16 for g in geoms[1:])


# ---------------------------------------------------------------------------
# Conv autotuning integration
# ---------------------------------------------------------------------------

def test_conv_candidates_alignment_and_default_first():
    from repro.tune import space
    m, n, k, ckw = 169, 128, 90, 30           # cin_g=10, kw=3
    for algo in ("baseline", "fip", "ffip"):
        cands = space.conv_candidates(m, n, k, ckw, algo)
        assert cands[0] == tuple(kops.choose_blocks(m, n, k, algo))
        assert len(cands) == len(set(cands))
        aligned = [c for c in cands[1:] if c[2] % ckw == 0]
        assert aligned, f"no ckw-aligned bk candidates for {algo}"
        if algo in ("fip", "ffip"):
            assert all(c[2] % 2 == 0 for c in cands)


def test_conv_candidates_odd_ckw_fip_uses_even_multiples():
    from repro.tune import space
    cands = space.conv_candidates(64, 32, 45, 15, "fip")   # odd ckw
    assert all(c[2] % 2 == 0 for c in cands)
    assert any(c[2] % 30 == 0 for c in cands[1:])          # 2*ckw multiples


def test_tune_conv_roundtrip_and_auto_block(tmp_path, monkeypatch):
    """tune_conv persists; lookup hits; GemmConfig(block='auto') resolves the
    tuned blocks at trace time for conv2d; a warm re-tune measures nothing."""
    from repro import tune
    from repro.tune import measure
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "sched.json"))
    tune.reset_stats()
    entry = tune.tune_conv(1, 9, 9, 4, 8, 3, 3, jnp.float32, pad=1,
                           algo="ffip", budget=2, iters=1)
    got = tune.lookup_conv_blocks("ffip", jnp.float32, 81, 8, 36, 12)
    assert got == (entry["blocks"]["bm"], entry["blocks"]["bn"],
                   entry["blocks"]["bk"])
    # warm: zero re-measurement
    pre = measure.counters["timed_candidates"]
    tune.tune_conv(1, 9, 9, 4, 8, 3, 3, jnp.float32, pad=1, algo="ffip",
                   budget=2, iters=1)
    assert measure.counters["timed_candidates"] == pre
    # block="auto" conv forward consumes the schedule (hit counter moves)
    p = vl.conv_init(jax.random.PRNGKey(0), 3, 3, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, 9, 4))
    hits0 = tune.stats["hits"]
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", block="auto")):
        out = vl.conv2d(x, p, pad=1)
    assert tune.stats["hits"] > hits0
    ref = vl.conv2d(x, p, pad=1)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_auto_block_float_fallback_keys_on_cfg_algo(tmp_path, monkeypatch):
    """quantized=True on params WITHOUT a 'q' entry runs the float cfg.algo
    kernel — the auto lookup must key on that algo (baseline here), not on
    the quantized effective algo (ffip)."""
    from repro import tune
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "s.json"))
    tune.reset_stats()
    tune.tune_conv(1, 9, 9, 4, 8, 3, 3, jnp.float32, pad=1, algo="baseline",
                   budget=1, iters=1)              # baseline schedule ONLY
    p = vl.conv_init(jax.random.PRNGKey(0), 3, 3, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 9, 9, 4))
    hits0, misses0 = tune.stats["hits"], tune.stats["misses"]
    with use_gemm(GemmConfig(algo="baseline", impl="pallas", quantized=True,
                             block="auto")):
        vl.conv2d(x, p, pad=1)
    assert tune.stats["hits"] > hits0
    assert tune.stats["misses"] == misses0


def test_auto_block_miss_falls_back(tmp_path, monkeypatch):
    from repro import tune
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "empty.json"))
    tune.reset_stats()
    p = vl.conv_init(jax.random.PRNGKey(0), 3, 3, 4, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 4))
    misses0 = tune.stats["misses"]
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", block="auto")):
        out = vl.conv2d(x, p, pad=1)
    assert tune.stats["misses"] > misses0
    assert bool(jnp.isfinite(out).all())


def test_explicit_block_tuple_applies(alexnet_small):
    model, params, x = alexnet_small
    ref = vm.apply(model, params, x)
    with use_gemm(GemmConfig(algo="ffip", impl="pallas", block=(16, 16, 8))):
        got = vm.apply(model, params, x)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_conv_key_distinguishes_ckw():
    from repro import tune
    k1 = tune.conv_key("ffip", jnp.float32, 64, 32, 90, 30)
    k2 = tune.conv_key("ffip", jnp.float32, 64, 32, 90, 10)
    assert k1 != k2
